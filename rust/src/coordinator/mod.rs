//! The automated tiling exploration flow (Fig. 3).
//!
//! ```text
//! G_in -> schedule -> layout L -> critical buffers B_i
//!      -> path discovery -> configs C_i -> transform -> G_i
//!      -> schedule+layout each -> L_min
//!      -> if L_min < L: G_opt = argmin, repeat; else next B_i; stop.
//! ```
//!
//! Candidate configurations are evaluated concurrently on OS threads
//! (each evaluation is an independent transform + schedule + layout).

use crate::analysis::{graph_macs, MemModel};
use crate::graph::fusion::fuse;
use crate::graph::{Graph, TensorId, TensorKind};
use crate::layout::{self, heuristic, Layout, LayoutOptions};
use crate::sched::{self, SchedOptions, Schedule};
use crate::tiling::discovery::{discover, DiscoveryOptions};
use crate::tiling::PathConfig;
use crate::transform::apply_tiling;

/// Measured cost of a graph under the full deployment flow.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Arena size of the planned layout (intermediate RAM incl. model
    /// I/O buffers).
    pub ram: usize,
    /// Static MAC count.
    pub macs: u64,
    /// Weight bytes (ROM).
    pub rom: usize,
    /// Schedule peak (== ram unless fragmentation).
    pub sched_peak: usize,
    pub sched_strategy: &'static str,
    pub layout_optimal: bool,
}

/// Flow tuning knobs.
#[derive(Debug, Clone)]
pub struct FlowOptions {
    pub sched: SchedOptions,
    pub layout: LayoutOptions,
    pub discovery: DiscoveryOptions,
    /// Cheap scheduling budget used while screening candidates; the
    /// winning graph is re-evaluated at full budget.
    pub screening_sched: SchedOptions,
    /// Maximum Fig-3 iterations (tiling applications).
    pub max_iterations: usize,
    /// Critical-buffer candidates examined per iteration.
    pub max_candidates: usize,
    /// Worker threads for candidate evaluation.
    pub threads: usize,
    /// §5.2 performance-optimized design point: reject configurations
    /// whose cumulative MAC overhead (vs. the *original* graph) exceeds
    /// this percentage. `None` = memory-optimized design (paper default).
    pub max_mac_overhead_pct: Option<f64>,
}

impl Default for FlowOptions {
    fn default() -> Self {
        FlowOptions {
            sched: SchedOptions::default(),
            layout: LayoutOptions::default(),
            discovery: DiscoveryOptions::default(),
            screening_sched: SchedOptions { bnb_node_budget: 50_000, use_sp: true },
            max_iterations: 8,
            max_candidates: 6,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            max_mac_overhead_pct: None,
        }
    }
}

/// One accepted tiling application.
#[derive(Debug, Clone)]
pub struct IterationLog {
    pub critical_buffer: String,
    pub config: String,
    pub ram_before: usize,
    pub ram_after: usize,
    pub configs_tested: usize,
}

/// Result of the full exploration.
#[derive(Debug, Clone)]
pub struct FlowResult {
    pub graph: Graph,
    pub initial: Evaluation,
    pub final_eval: Evaluation,
    pub iterations: Vec<IterationLog>,
    pub configs_tested: usize,
    pub elapsed: std::time::Duration,
}

impl FlowResult {
    pub fn ram_savings_pct(&self) -> f64 {
        if self.initial.ram == 0 {
            return 0.0;
        }
        100.0 * (self.initial.ram as f64 - self.final_eval.ram as f64) / self.initial.ram as f64
    }
    pub fn mac_overhead_pct(&self) -> f64 {
        if self.initial.macs == 0 {
            return 0.0;
        }
        100.0 * (self.final_eval.macs as f64 - self.initial.macs as f64) / self.initial.macs as f64
    }
}

/// Evaluate a graph end to end: fuse, schedule, plan layout.
pub fn evaluate(g: &Graph, sched_opts: SchedOptions, layout_opts: LayoutOptions) -> Evaluation {
    let grouping = fuse(g);
    let m = MemModel::new(g, &grouping);
    let s = sched::schedule(&m, sched_opts);
    let l = layout::plan(&m, &s.order, layout_opts);
    Evaluation {
        ram: l.total,
        macs: graph_macs(g),
        rom: g.rom_bytes(),
        sched_peak: s.peak,
        sched_strategy: s.strategy,
        layout_optimal: l.optimal,
    }
}

/// Schedule + layout, returning all three artifacts (for reports).
pub fn plan_graph<'a>(
    g: &'a Graph,
    grouping: &'a crate::graph::fusion::Grouping,
    opts: &FlowOptions,
) -> (MemModel<'a>, Schedule, Layout) {
    let m = MemModel::new(g, grouping);
    let s = sched::schedule(&m, opts.sched);
    let l = layout::plan(&m, &s.order, opts.layout);
    (m, s, l)
}

/// Critical-buffer detection (§4.3): intermediate buffers that are
/// "solely responsible" for the layout size — removing one shrinks a
/// quick re-layout. Returned largest-first.
pub fn critical_buffers(m: &MemModel, schedule: &[usize], l: &Layout) -> Vec<TensorId> {
    let conflicts = m.conflicts(schedule);
    let mut cands: Vec<(usize, TensorId)> = Vec::new();
    for (b, &t) in m.buffers.iter().enumerate() {
        let tensor = m.g.tensor(t);
        // Model I/O cannot be tiled.
        if tensor.kind == TensorKind::Input || m.is_output[b] {
            continue;
        }
        // Quick what-if: re-layout with this buffer removed.
        let mut sizes = m.sizes.clone();
        sizes[b] = 0;
        let without = heuristic::first_fit_by_size(&sizes, &conflicts);
        if without.total < l.total {
            cands.push((m.sizes[b], t));
        }
    }
    cands.sort_by_key(|&(s, _)| std::cmp::Reverse(s));
    cands.into_iter().map(|(_, t)| t).collect()
}

/// Screen a batch of configs in parallel; returns `(best_ram, index)`.
/// `mac_cap` is the absolute MAC budget (original MACs scaled by the
/// overhead threshold); configurations exceeding it are rejected.
fn screen_configs(
    g: &Graph,
    configs: &[PathConfig],
    opts: &FlowOptions,
    mac_cap: Option<u64>,
) -> (Option<(usize, usize)>, usize) {
    let screen_one = |g: &Graph, c: &PathConfig, opts: &FlowOptions| {
        screen_one(g, c, opts, mac_cap)
    };
    let results: Vec<Option<usize>> = if opts.threads <= 1 || configs.len() <= 1 {
        configs.iter().map(|c| screen_one(g, c, opts)).collect()
    } else {
        let mut results: Vec<Option<usize>> = vec![None; configs.len()];
        let next = std::sync::atomic::AtomicUsize::new(0);
        let slots: Vec<std::sync::Mutex<Option<usize>>> =
            (0..configs.len()).map(|_| std::sync::Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..opts.threads.min(configs.len()) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= configs.len() {
                        break;
                    }
                    let r = screen_one(g, &configs[i], opts);
                    *slots[i].lock().unwrap() = r;
                });
            }
        });
        for (i, s) in slots.into_iter().enumerate() {
            results[i] = s.into_inner().unwrap();
        }
        results
    };
    let tested = results.len();
    let best = results
        .into_iter()
        .enumerate()
        .filter_map(|(i, r)| r.map(|ram| (ram, i)))
        .min();
    (best, tested)
}

/// Evaluate one candidate cheaply. `None` when the transform is invalid
/// for this graph (e.g. partition count exceeding channels) or the MAC
/// budget is exceeded (§5.2 performance-optimized design).
fn screen_one(g: &Graph, cfg: &PathConfig, opts: &FlowOptions, mac_cap: Option<u64>) -> Option<usize> {
    let tiled = apply_tiling(g, cfg).ok()?;
    if let Some(cap) = mac_cap {
        if graph_macs(&tiled) > cap {
            return None;
        }
    }
    let grouping = fuse(&tiled);
    let m = MemModel::new(&tiled, &grouping);
    let s = sched::schedule(&m, opts.screening_sched);
    // Screening uses the first-fit layout (fast); the exact planner runs
    // on the winner only. First-fit is an upper bound, so a winning
    // candidate never gets worse after exact planning.
    let conflicts = m.conflicts(&s.order);
    let l = heuristic::first_fit_by_size(&m.sizes, &conflicts);
    Some(l.total)
}

/// Run the full Fig-3 exploration on `g`.
pub fn optimize(g: &Graph, opts: &FlowOptions) -> FlowResult {
    let t0 = std::time::Instant::now();
    let initial = evaluate(g, opts.sched, opts.layout);
    // MAC budget relative to the *original* graph, so overhead cannot
    // accumulate past the threshold over iterations.
    let mac_cap = opts
        .max_mac_overhead_pct
        .map(|pct| (initial.macs as f64 * (1.0 + pct / 100.0)).floor() as u64);
    let mut current = g.clone();
    let mut current_eval = initial.clone();
    let mut iterations = Vec::new();
    let mut configs_tested = 0usize;

    'outer: for _ in 0..opts.max_iterations {
        let grouping = fuse(&current);
        let (m, s, l) = plan_graph(&current, &grouping, opts);
        let candidates = critical_buffers(&m, &s.order, &l);

        for t in candidates.into_iter().take(opts.max_candidates) {
            let configs = discover(&current, t, &opts.discovery);
            if configs.is_empty() {
                continue;
            }
            let (best, tested) = screen_configs(&current, &configs, opts, mac_cap);
            configs_tested += tested;
            let Some((_, idx)) = best else { continue };
            // Re-evaluate the winner at full fidelity.
            let tiled = match apply_tiling(&current, &configs[idx]) {
                Ok(t) => t,
                Err(_) => continue,
            };
            let eval = evaluate(&tiled, opts.sched, opts.layout);
            if eval.ram < current_eval.ram {
                iterations.push(IterationLog {
                    critical_buffer: current.tensor(t).name.clone(),
                    config: configs[idx].describe(&current),
                    ram_before: current_eval.ram,
                    ram_after: eval.ram,
                    configs_tested: tested,
                });
                current = tiled;
                current_eval = eval;
                continue 'outer; // re-plan the new graph (Fig 3 loop-back)
            }
        }
        break; // no candidate improved: flow terminates
    }

    FlowResult {
        graph: current,
        initial,
        final_eval: current_eval,
        iterations,
        configs_tested,
        elapsed: t0.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn txt_flow_reduces_memory_substantially() {
        let g = crate::models::txt();
        let r = optimize(&g, &FlowOptions::default());
        assert!(
            r.ram_savings_pct() > 50.0,
            "TXT should tile its embedding buffer: {:.1}% (init {} -> {})",
            r.ram_savings_pct(),
            r.initial.ram,
            r.final_eval.ram
        );
        assert_eq!(r.final_eval.macs, r.initial.macs, "FDT adds no MACs");
        // The tiled graph still computes the same function.
        let inputs = crate::exec::random_inputs(&g, 3);
        let a = crate::exec::run(&g, &inputs).unwrap();
        let b = crate::exec::run(&r.graph, &inputs).unwrap();
        assert!(crate::exec::max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn fdt_only_flow_never_adds_macs() {
        let mut opts = FlowOptions::default();
        opts.discovery.enable_ffmt = false;
        for g in [crate::models::radar(), crate::models::fig5_example()] {
            let r = optimize(&g, &opts);
            assert_eq!(r.final_eval.macs, r.initial.macs, "{}", g.name);
        }
    }
}
