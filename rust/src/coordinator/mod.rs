//! The automated tiling exploration flow (Fig. 3).
//!
//! ```text
//! G_in -> schedule -> layout L -> critical buffers B_i
//!      -> path discovery -> configs C_i -> transform -> G_i
//!      -> schedule+layout each -> L_min
//!      -> if L_min < L: G_opt = argmin, repeat; else next B_i; stop.
//! ```
//!
//! Candidate evaluation is built for speed without changing any result:
//!
//! * **Fingerprint memo** — schedule/layout screening results are keyed
//!   by the post-transform graph's structural fingerprint
//!   ([`Graph::fingerprint`]), so structurally identical candidates are
//!   solved once per flow run.
//! * **Incumbent cutoff** — the best RAM found so far bounds every
//!   screening: a candidate is abandoned before any search the moment
//!   [`sched::peak_lower_bound`] reaches the incumbent, and the layout
//!   pass is skipped outright when the computed schedule peak already
//!   loses (the arena can never undercut the peak). Both shortcuts are
//!   provable rejections; when a candidate has no config below the
//!   incumbent at all, an exact re-screen reproduces the legacy argmin
//!   (the cutoff-bounded B&B variant, [`sched::schedule_with_cutoff`],
//!   is deliberately *not* used here: its returned order is not stable
//!   under budget truncation, which would break result-identity).
//! * **Plan reuse** — the winner's full-fidelity schedule + layout are
//!   carried into the next Fig-3 iteration instead of re-solved, and
//!   full-fidelity layouts are memoized by instance ([`layout::Memo`]).
//! * **Persistent screening pool** — one set of worker threads serves
//!   the whole run through a shared work queue (no per-candidate
//!   `thread::scope` spawn/join churn).
//!
//! All four optimizations are result-preserving; [`FlowOptions::legacy`]
//! disables them so benches can measure the speedup and tests can assert
//! byte-identical [`Evaluation`]s.

use crate::analysis::{graph_macs, MemModel};
use crate::error::{FdtError, FdtResult};
use crate::graph::fusion::{fuse, Grouping};
use crate::graph::{Graph, TensorId, TensorKind};
use crate::layout::{self, heuristic, Layout, LayoutOptions};
use crate::sched::{self, SchedOptions, Schedule};
use crate::tiling::discovery::{discover, DiscoveryOptions};
use crate::tiling::PathConfig;
use crate::transform::apply_tiling;
use crate::util::FnvHashMap;
use std::sync::{mpsc, Arc, Mutex};

/// Measured cost of a graph under the full deployment flow.
#[derive(Debug, Clone)]
pub struct Evaluation {
    /// Arena size of the planned layout (intermediate RAM incl. model
    /// I/O buffers).
    pub ram: usize,
    /// Static MAC count.
    pub macs: u64,
    /// Weight bytes (ROM).
    pub rom: usize,
    /// Schedule peak (== ram unless fragmentation).
    pub sched_peak: usize,
    pub sched_strategy: &'static str,
    pub layout_optimal: bool,
}

/// Flow tuning knobs.
#[derive(Debug, Clone)]
pub struct FlowOptions {
    pub sched: SchedOptions,
    pub layout: LayoutOptions,
    pub discovery: DiscoveryOptions,
    /// Cheap scheduling budget used while screening candidates; the
    /// winning graph is re-evaluated at full budget.
    pub screening_sched: SchedOptions,
    /// Maximum Fig-3 iterations (tiling applications).
    pub max_iterations: usize,
    /// Critical-buffer candidates examined per iteration.
    pub max_candidates: usize,
    /// Worker threads for candidate evaluation.
    pub threads: usize,
    /// §5.2 performance-optimized design point: reject configurations
    /// whose cumulative MAC overhead (vs. the *original* graph) exceeds
    /// this percentage. `None` = memory-optimized design (paper default).
    pub max_mac_overhead_pct: Option<f64>,
    /// Memoize screening by post-transform fingerprint and reuse
    /// full-fidelity plans across iterations.
    pub memoize: bool,
    /// Bound screening by the incumbent best RAM (early B&B abandon +
    /// layout skip).
    pub incumbent_cutoff: bool,
}

impl Default for FlowOptions {
    fn default() -> Self {
        FlowOptions {
            sched: SchedOptions::default(),
            layout: LayoutOptions::default(),
            discovery: DiscoveryOptions::default(),
            screening_sched: SchedOptions { bnb_node_budget: 50_000, wall_ms: None, use_sp: true },
            max_iterations: 8,
            max_candidates: 6,
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            max_mac_overhead_pct: None,
            memoize: true,
            incumbent_cutoff: true,
        }
    }
}

impl FlowOptions {
    /// Pre-overhaul behaviour: exhaustive discovery (no dedup/dominance
    /// pruning), no fingerprint memo, no incumbent-bounded screening, no
    /// plan reuse. The optimizations are result-preserving, so this
    /// produces identical [`Evaluation`]s — it exists so benches can
    /// measure the speedup and tests can assert the equivalence.
    pub fn legacy() -> FlowOptions {
        FlowOptions {
            discovery: DiscoveryOptions { dedup: false, ..DiscoveryOptions::default() },
            memoize: false,
            incumbent_cutoff: false,
            ..FlowOptions::default()
        }
    }
}

/// One accepted tiling application.
#[derive(Debug, Clone)]
pub struct IterationLog {
    pub critical_buffer: String,
    pub config: String,
    pub ram_before: usize,
    pub ram_after: usize,
    pub configs_tested: usize,
}

/// Result of the full exploration.
#[derive(Debug, Clone)]
pub struct FlowResult {
    pub graph: Graph,
    pub initial: Evaluation,
    pub final_eval: Evaluation,
    pub iterations: Vec<IterationLog>,
    pub configs_tested: usize,
    pub elapsed: std::time::Duration,
    /// Human-readable notes recorded whenever the flow gracefully
    /// degraded instead of failing: solver budgets that ran out (best
    /// incumbent kept), screening workers that panicked on a candidate
    /// (candidate skipped). Empty on a fully clean run.
    pub degradations: Vec<String>,
}

impl FlowResult {
    pub fn ram_savings_pct(&self) -> f64 {
        if self.initial.ram == 0 {
            return 0.0;
        }
        100.0 * (self.initial.ram as f64 - self.final_eval.ram as f64) / self.initial.ram as f64
    }
    pub fn mac_overhead_pct(&self) -> f64 {
        if self.initial.macs == 0 {
            return 0.0;
        }
        100.0 * (self.final_eval.macs as f64 - self.initial.macs as f64) / self.initial.macs as f64
    }
}

/// Evaluate a graph end to end: fuse, schedule, plan layout.
pub fn evaluate(g: &Graph, sched_opts: SchedOptions, layout_opts: LayoutOptions) -> Evaluation {
    let grouping = fuse(g);
    let m = MemModel::new(g, &grouping);
    let s = sched::schedule(&m, sched_opts);
    let l = layout::plan(&m, &s.order, layout_opts);
    Evaluation {
        ram: l.total,
        macs: graph_macs(g),
        rom: g.rom_bytes(),
        sched_peak: s.peak,
        sched_strategy: s.strategy,
        layout_optimal: l.optimal,
    }
}

/// Schedule + layout, returning all three artifacts (for reports).
pub fn plan_graph<'a>(
    g: &'a Graph,
    grouping: &'a Grouping,
    opts: &FlowOptions,
) -> (MemModel<'a>, Schedule, Layout) {
    let m = MemModel::new(g, grouping);
    let s = sched::schedule(&m, opts.sched);
    let l = layout::plan(&m, &s.order, opts.layout);
    (m, s, l)
}

/// Plan → executable handoff: compile `g` for the native int8 arena
/// executor against the *same* full-fidelity schedule + layout the flow's
/// evaluation reports, so the executor's arena is exactly the flow's RAM
/// number (`FDT_ARENA_BYTES`).
pub fn int8_executable(
    g: &Graph,
    opts: &FlowOptions,
    cal: &crate::quant::Calibration,
) -> FdtResult<crate::exec::int8::Int8Executable> {
    g.validate()?;
    let qm = crate::quant::int8::compile(g, cal)?;
    let grouping = fuse(g);
    let (m, s, l) = plan_graph(g, &grouping, opts);
    crate::verify::verify_plan(g, &grouping, &s.order, &l)?;
    let exe = crate::exec::int8::Int8Executable::compile(g, &qm, &grouping, &s.order, &l, &m)?;
    crate::verify::verify_int8(&exe)?;
    Ok(exe)
}

/// Critical-buffer detection (§4.3): intermediate buffers that are
/// "solely responsible" for the layout size — removing one shrinks a
/// quick re-layout. Returned largest-first.
pub fn critical_buffers(m: &MemModel, schedule: &[usize], l: &Layout) -> Vec<TensorId> {
    let conflicts = m.conflicts(schedule);
    let mut cands: Vec<(usize, TensorId)> = Vec::new();
    for (b, &t) in m.buffers.iter().enumerate() {
        let tensor = m.g.tensor(t);
        // Model I/O cannot be tiled.
        if tensor.kind == TensorKind::Input || m.is_output[b] {
            continue;
        }
        // Quick what-if: re-layout with this buffer removed.
        let mut sizes = m.sizes.clone();
        sizes[b] = 0;
        let without = heuristic::first_fit_by_size(&sizes, &conflicts);
        if without.total < l.total {
            cands.push((m.sizes[b], t));
        }
    }
    cands.sort_by_key(|&(s, _)| std::cmp::Reverse(s));
    cands.into_iter().map(|(_, t)| t).collect()
}

/// Outcome of screening one configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Screen {
    /// Transform invalid for this graph, or MAC budget exceeded.
    Invalid,
    /// Provably unable to beat the incumbent: the schedule peak lower
    /// bound — or the computed screening peak — already reaches it, and
    /// the screened first-fit total can only be larger. The exact value
    /// was not computed.
    AboveIncumbent,
    /// Legacy-exact screened arena upper bound (first-fit total).
    Ram(usize),
}

/// Screening results memo: post-transform fingerprint -> [`Screen`].
/// `Invalid` and `Ram` are structure-determined and always reusable;
/// `AboveIncumbent` stays valid because the incumbent only decreases
/// over a run (an exact re-screen upgrades such entries to `Ram`).
type ScreenMemo = FnvHashMap<u64, Screen>;

/// Shared, immutable screening context.
#[derive(Clone)]
struct ScreenCtx {
    opts: Arc<FlowOptions>,
    /// Absolute MAC budget (original MACs scaled by the overhead
    /// threshold); configurations exceeding it are rejected (§5.2).
    mac_cap: Option<u64>,
    memo: Arc<Mutex<ScreenMemo>>,
}

/// Evaluate one candidate cheaply. `cutoff` is the incumbent best RAM
/// (`usize::MAX` disables bounding). With `exact` set, the incumbent
/// shortcuts are bypassed and the result is always `Invalid` or a
/// legacy-exact `Ram` — used by the ambiguous-candidate fallback in
/// [`screen_configs`], which needs the same values the pre-overhaul flow
/// would have ranked by.
fn screen_one(g: &Graph, cfg: &PathConfig, ctx: &ScreenCtx, cutoff: usize, exact: bool) -> Screen {
    let Ok(tiled) = apply_tiling(g, cfg) else {
        return Screen::Invalid;
    };
    if let Some(cap) = ctx.mac_cap {
        if graph_macs(&tiled) > cap {
            return Screen::Invalid;
        }
    }
    let fp = if ctx.opts.memoize {
        let fp = tiled.fingerprint();
        match ctx.memo.lock().unwrap_or_else(|p| p.into_inner()).get(&fp).copied() {
            Some(hit @ (Screen::Invalid | Screen::Ram(_))) => return hit,
            Some(Screen::AboveIncumbent) if !exact => return Screen::AboveIncumbent,
            _ => {}
        }
        Some(fp)
    } else {
        None
    };
    let grouping = fuse(&tiled);
    let m = MemModel::new(&tiled, &grouping);
    // Abandon before any search: a provable peak lower bound at/above
    // the incumbent means even the exact planner cannot beat it.
    if !exact && sched::peak_lower_bound(&m) >= cutoff {
        if let Some(fp) = fp {
            ctx.memo.lock().unwrap_or_else(|p| p.into_inner()).insert(fp, Screen::AboveIncumbent);
        }
        return Screen::AboveIncumbent;
    }
    let s = sched::schedule(&m, ctx.opts.screening_sched);
    // The screened first-fit total can never undercut the schedule peak,
    // so a peak at/above the incumbent loses outright — skip the layout.
    let result = if !exact && s.peak >= cutoff {
        Screen::AboveIncumbent
    } else {
        // Screening uses the first-fit layout (fast); the exact planner
        // runs on the winner only. First-fit is an upper bound, so a
        // winning candidate never gets worse after exact planning.
        let conflicts = m.conflicts(&s.order);
        Screen::Ram(heuristic::first_fit_by_size(&m.sizes, &conflicts).total)
    };
    if let Some(fp) = fp {
        ctx.memo.lock().unwrap_or_else(|p| p.into_inner()).insert(fp, result);
    }
    result
}

/// A unit of screening work handed to the persistent pool.
struct Job {
    batch: u64,
    idx: usize,
    graph: Arc<Graph>,
    configs: Arc<Vec<PathConfig>>,
    ctx: ScreenCtx,
    cutoff: usize,
    exact: bool,
}

/// Persistent screening workers: spawned once per [`optimize`] run and
/// fed through a shared queue, so successive candidate batches neither
/// respawn threads nor pay a scope join beyond their own results.
struct ScreenPool {
    tx: Option<mpsc::Sender<Job>>,
    results: mpsc::Receiver<(u64, usize, Result<Screen, String>)>,
    batch: u64,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl ScreenPool {
    fn new(threads: usize) -> ScreenPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let (rtx, results) = mpsc::channel();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::new();
        for _ in 0..threads.max(1) {
            let rx = Arc::clone(&rx);
            let rtx = rtx.clone();
            handles.push(std::thread::spawn(move || loop {
                // Holding the lock across `recv` is fine: blocked workers
                // queue on the mutex instead of the channel, with the
                // same one-job-per-wakeup distribution.
                let job = rx.lock().unwrap_or_else(|p| p.into_inner()).recv();
                let Ok(j) = job else { break };
                // A panicking config must still produce a result, or the
                // collector would wait forever. The payload is forwarded
                // so the collector re-raises it loudly on the main thread
                // (the pre-overhaul `thread::scope` propagated panics at
                // its join; masking them as Invalid would hide bugs).
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    screen_one(&j.graph, &j.configs[j.idx], &j.ctx, j.cutoff, j.exact)
                }))
                .map_err(|p| {
                    p.downcast_ref::<&str>()
                        .map(|s| s.to_string())
                        .or_else(|| p.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "non-string panic payload".to_string())
                });
                if rtx.send((j.batch, j.idx, r)).is_err() {
                    break;
                }
            }));
        }
        ScreenPool { tx: Some(tx), results, batch: 0, handles }
    }

    /// Screen every config of one candidate; returns results by index.
    /// A worker panic demotes that config to [`Screen::Invalid`] and is
    /// recorded in `degradations` — one pathological candidate must not
    /// take the whole exploration down.
    fn run_batch(
        &mut self,
        graph: &Arc<Graph>,
        configs: &Arc<Vec<PathConfig>>,
        ctx: &ScreenCtx,
        cutoff: usize,
        exact: bool,
        degradations: &mut Vec<String>,
    ) -> Vec<Screen> {
        self.batch += 1;
        let n = configs.len();
        let tx = match self.tx.as_ref() {
            Some(tx) => tx,
            None => return vec![Screen::Invalid; n], // pool shut down
        };
        let mut sent = 0usize;
        for idx in 0..n {
            if tx
                .send(Job {
                    batch: self.batch,
                    idx,
                    graph: Arc::clone(graph),
                    configs: Arc::clone(configs),
                    ctx: ctx.clone(),
                    cutoff,
                    exact,
                })
                .is_err()
            {
                degradations.push("screening pool hung up; remaining configs skipped".to_string());
                break;
            }
            sent += 1;
        }
        let mut out = vec![Screen::Invalid; n];
        for _ in 0..sent {
            let Ok((batch, idx, r)) = self.results.recv() else {
                degradations.push("screening workers died; partial results kept".to_string());
                break;
            };
            debug_assert_eq!(batch, self.batch, "stale screening result");
            match r {
                Ok(s) => out[idx] = s,
                Err(msg) => {
                    degradations
                        .push(format!("screening panicked on candidate config {idx}: {msg}"));
                }
            }
        }
        out
    }
}

impl Drop for ScreenPool {
    fn drop(&mut self) {
        self.tx.take(); // closing the queue stops the workers
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Best screened `(ram, index)` over a result set.
fn best_ram(results: &[Screen]) -> Option<(usize, usize)> {
    results
        .iter()
        .enumerate()
        .filter_map(|(i, r)| match r {
            Screen::Ram(ram) => Some((*ram, i)),
            _ => None,
        })
        .min()
}

/// Screen a batch of configs; returns `(best_ram_and_index, tested)`.
///
/// Result-identical to the pre-overhaul flow: `AboveIncumbent` configs
/// have a legacy screened value `>= cutoff`, so they can only influence
/// the argmin when *no* config screens below the incumbent. In that
/// ambiguous case every config is re-screened exactly (memo hits make
/// the already-valued ones free) so the winner the legacy flow would
/// have full-evaluated is reproduced bit-for-bit.
fn screen_configs(
    g: &Arc<Graph>,
    configs: &Arc<Vec<PathConfig>>,
    ctx: &ScreenCtx,
    cutoff: usize,
    pool: &mut Option<ScreenPool>,
    degradations: &mut Vec<String>,
) -> (Option<(usize, usize)>, usize) {
    let mut run = |exact: bool, degradations: &mut Vec<String>| -> Vec<Screen> {
        if ctx.opts.threads <= 1 || configs.len() <= 1 {
            // Sequential path: contain per-config panics exactly like the
            // pool does, so both paths degrade rather than unwind.
            configs
                .iter()
                .enumerate()
                .map(|(idx, c)| {
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        screen_one(g, c, ctx, cutoff, exact)
                    }))
                    .unwrap_or_else(|p| {
                        let msg = p
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| p.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".to_string());
                        degradations
                            .push(format!("screening panicked on candidate config {idx}: {msg}"));
                        Screen::Invalid
                    })
                })
                .collect()
        } else {
            let p = pool.get_or_insert_with(|| ScreenPool::new(ctx.opts.threads));
            p.run_batch(g, configs, ctx, cutoff, exact, degradations)
        }
    };
    let results = run(false, degradations);
    let tested = results.len();
    let mut best = best_ram(&results);
    let ambiguous = !best.is_some_and(|(ram, _)| ram < cutoff)
        && results.iter().any(|r| matches!(r, Screen::AboveIncumbent));
    if ambiguous {
        best = best_ram(&run(true, degradations));
    }
    (best, tested)
}

/// Full-fidelity evaluation that also returns the plan, so the Fig-3
/// loop-back can reuse it instead of re-solving the accepted graph.
fn evaluate_planned(
    g: &Graph,
    opts: &FlowOptions,
    layout_memo: &mut layout::Memo,
) -> (Evaluation, Grouping, Schedule, Layout) {
    let grouping = fuse(g);
    let (eval, s, l) = {
        let m = MemModel::new(g, &grouping);
        let s = sched::schedule(&m, opts.sched);
        let l = if opts.memoize {
            layout::plan_memoized(&m, &s.order, opts.layout, layout_memo)
        } else {
            layout::plan(&m, &s.order, opts.layout)
        };
        let eval = Evaluation {
            ram: l.total,
            macs: graph_macs(g),
            rom: g.rom_bytes(),
            sched_peak: s.peak,
            sched_strategy: s.strategy,
            layout_optimal: l.optimal,
        };
        (eval, s, l)
    };
    // Mandatory post-planning gate: no plan leaves the flow unverified.
    // The typed counterexample is re-raised through the catch_unwind
    // backstop in `try_optimize`, which downcasts it back into the
    // structured `FdtError::PlanVerification` (and `optimize` panics
    // with its rendered diagnostic, as for any other flow failure).
    if let Err(e) = crate::verify::verify_plan(g, &grouping, &s.order, &l) {
        std::panic::panic_any(e);
    }
    (eval, grouping, s, l)
}

/// Run the full Fig-3 exploration on `g`.
///
/// Infallible wrapper kept for the many internal callers whose graphs
/// are valid by construction: a malformed graph (or a residual flow bug)
/// panics with the typed diagnostic. Library callers should prefer
/// [`try_optimize`], which returns it as an error instead.
pub fn optimize(g: &Graph, opts: &FlowOptions) -> FlowResult {
    match try_optimize(g, opts) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// Fault-tolerant flow entry point: pre-flight-validates `g` (dangling
/// refs, cycles, shape mismatches, zero-extent inputs) and converts any
/// residual panic inside the exploration into [`FdtError`] — no panic
/// escapes this API.
pub fn try_optimize(g: &Graph, opts: &FlowOptions) -> FdtResult<FlowResult> {
    g.validate()?;
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| optimize_inner(g, opts))).map_err(
        // A typed error thrown through the panic path (the plan-verifier
        // gate uses `panic_any`) survives as itself; anything else is a
        // residual bug and keeps the legacy string mapping.
        |p| match p.downcast::<FdtError>() {
            Ok(e) => *e,
            Err(p) => FdtError::Other {
                reason: p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "flow panicked with a non-string payload".to_string()),
            },
        },
    )
}

fn optimize_inner(g: &Graph, opts: &FlowOptions) -> FlowResult {
    let t0 = std::time::Instant::now();
    let mut layout_memo = layout::Memo::default();
    let mut degradations: Vec<String> = Vec::new();
    let (initial, grouping0, s0, l0) = evaluate_planned(g, opts, &mut layout_memo);
    if s0.degraded {
        degradations
            .push("initial schedule: exact search budget exhausted; kept best incumbent".into());
    }
    if !l0.optimal {
        degradations
            .push("initial layout: exact placer budget exhausted; kept best heuristic".into());
    }
    // MAC budget relative to the *original* graph, so overhead cannot
    // accumulate past the threshold over iterations.
    let mac_cap = opts
        .max_mac_overhead_pct
        .map(|pct| (initial.macs as f64 * (1.0 + pct / 100.0)).floor() as u64);
    let ctx = ScreenCtx {
        opts: Arc::new(opts.clone()),
        mac_cap,
        memo: Arc::new(Mutex::new(ScreenMemo::default())),
    };
    let mut pool: Option<ScreenPool> = None;
    let mut current: Arc<Graph> = Arc::new(g.clone());
    let mut current_eval = initial.clone();
    let mut iterations = Vec::new();
    let mut configs_tested = 0usize;
    // Plan of `current`, seeded from the initial evaluation and replaced
    // by the winner's full-fidelity plan on every acceptance (legacy mode
    // re-solves at the loop head like the pre-overhaul flow did).
    let mut planned: Option<(Grouping, Schedule, Layout)> =
        opts.memoize.then_some((grouping0, s0, l0));

    'outer: for _ in 0..opts.max_iterations {
        let (grouping, s, l) = match planned.take() {
            Some(p) => p,
            None => {
                let (_, gr, s, l) = evaluate_planned(&current, opts, &mut layout_memo);
                (gr, s, l)
            }
        };
        let candidates = {
            let m = MemModel::new(&current, &grouping);
            critical_buffers(&m, &s.order, &l)
        };
        let cutoff = if opts.incumbent_cutoff { current_eval.ram } else { usize::MAX };

        for t in candidates.into_iter().take(opts.max_candidates) {
            let configs = Arc::new(discover(&current, t, &opts.discovery));
            if configs.is_empty() {
                continue;
            }
            let (best, tested) =
                screen_configs(&current, &configs, &ctx, cutoff, &mut pool, &mut degradations);
            configs_tested += tested;
            let Some((_, idx)) = best else { continue };
            // Re-evaluate the winner at full fidelity.
            let tiled = match apply_tiling(&current, &configs[idx]) {
                Ok(t) => t,
                Err(_) => continue,
            };
            let (eval, gr2, s2, l2) = evaluate_planned(&tiled, opts, &mut layout_memo);
            if eval.ram < current_eval.ram {
                if s2.degraded {
                    degradations.push(format!(
                        "iteration {}: schedule budget exhausted on accepted graph",
                        iterations.len()
                    ));
                }
                if !l2.optimal {
                    degradations.push(format!(
                        "iteration {}: layout placer budget exhausted on accepted graph",
                        iterations.len()
                    ));
                }
                iterations.push(IterationLog {
                    critical_buffer: current.tensor(t).name.clone(),
                    config: configs[idx].describe(&current),
                    ram_before: current_eval.ram,
                    ram_after: eval.ram,
                    configs_tested: tested,
                });
                current = Arc::new(tiled);
                current_eval = eval;
                planned = opts.memoize.then_some((gr2, s2, l2));
                continue 'outer; // re-plan the new graph (Fig 3 loop-back)
            }
        }
        break; // no candidate improved: flow terminates
    }

    FlowResult {
        graph: Arc::try_unwrap(current).unwrap_or_else(|a| (*a).clone()),
        initial,
        final_eval: current_eval,
        iterations,
        configs_tested,
        elapsed: t0.elapsed(),
        degradations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn txt_flow_reduces_memory_substantially() {
        let g = crate::models::txt();
        let r = optimize(&g, &FlowOptions::default());
        assert!(
            r.ram_savings_pct() > 50.0,
            "TXT should tile its embedding buffer: {:.1}% (init {} -> {})",
            r.ram_savings_pct(),
            r.initial.ram,
            r.final_eval.ram
        );
        assert_eq!(r.final_eval.macs, r.initial.macs, "FDT adds no MACs");
        // The tiled graph still computes the same function.
        let inputs = crate::exec::random_inputs(&g, 3);
        let a = crate::exec::run(&g, &inputs).unwrap();
        let b = crate::exec::run(&r.graph, &inputs).unwrap();
        assert!(crate::exec::max_abs_diff(&a, &b) < 1e-4);
    }

    #[test]
    fn fdt_only_flow_never_adds_macs() {
        let mut opts = FlowOptions::default();
        opts.discovery.enable_ffmt = false;
        for g in [crate::models::radar(), crate::models::fig5_example()] {
            let r = optimize(&g, &opts);
            assert_eq!(r.final_eval.macs, r.initial.macs, "{}", g.name);
        }
    }

    #[test]
    fn legacy_options_disable_every_speedup() {
        let o = FlowOptions::legacy();
        assert!(!o.memoize && !o.incumbent_cutoff && !o.discovery.dedup);
    }
}
