//! Persistent cross-run screening memo.
//!
//! The coordinator's fingerprint memo (post-transform [`Graph::fingerprint`]
//! → [`Screen`](super::Screen)) is solved once per flow run; this module
//! persists the cutoff-independent part of it to disk so repeated
//! explorations of the same model family are near-instant across
//! processes.
//!
//! * **Location** — `FDT_MEMO_DIR`, else `$XDG_CACHE_HOME/fdt`, else
//!   `~/.cache/fdt` (see [`default_dir`]). The library never touches the
//!   cache unless [`FlowOptions::memo_dir`](super::FlowOptions::memo_dir)
//!   is set; the `fdt optimize` CLI enables it by default (`--no-memo`
//!   opts out).
//! * **Keying** — one versioned JSON file per
//!   `(graph fingerprint, screening-options hash)` pair; the body repeats
//!   both keys and the loader verifies them, so a renamed or stale file
//!   can never leak foreign entries into a run.
//! * **What persists** — only `Invalid` and `Ram` screens: both are
//!   determined by the tiled graph + screening options alone.
//!   `AboveIncumbent` is relative to the run's incumbent cutoff and is
//!   never written.
//! * **Failure policy** — a corrupt, truncated, wrong-version or
//!   mismatched-key file degrades to a cold run with a typed
//!   [`FdtError::MemoCache`] warning recorded in the flow's
//!   degradations; so does an unwritable cache dir at save time. Never a
//!   panic, never a wrong plan: entries only seed the in-process memo,
//!   and every plan that leaves the flow still passes the `verify` gate.

use super::Screen;
use crate::error::FdtError;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Format version; bump whenever screening semantics change so stale
/// caches are ignored (with a warning) instead of misinterpreted.
pub const MEMO_VERSION: u64 = 1;

/// What the persistent memo did for one flow run (reported in
/// [`FlowResult::memo`](super::FlowResult::memo) and printed by the CLI).
#[derive(Debug, Clone)]
pub struct MemoStats {
    /// The cache file backing this run.
    pub path: PathBuf,
    /// Entries loaded from a previous run (0 = cold).
    pub loaded: usize,
    /// Screening memo hits during this run (persistent + in-run).
    pub hits: u64,
    /// Entries written back at the end of the run.
    pub stored: usize,
}

/// Resolve the default cache directory: `FDT_MEMO_DIR`, else
/// `$XDG_CACHE_HOME/fdt`, else `~/.cache/fdt`. `None` when no home is
/// resolvable (the CLI then runs memo-less).
pub fn default_dir() -> Option<PathBuf> {
    if let Ok(d) = std::env::var("FDT_MEMO_DIR") {
        if !d.is_empty() {
            return Some(PathBuf::from(d));
        }
    }
    if let Ok(d) = std::env::var("XDG_CACHE_HOME") {
        if !d.is_empty() {
            return Some(Path::new(&d).join("fdt"));
        }
    }
    std::env::var("HOME")
        .ok()
        .filter(|h| !h.is_empty())
        .map(|h| Path::new(&h).join(".cache").join("fdt"))
}

/// One run's handle on its cache file.
pub(super) struct Store {
    path: PathBuf,
    graph_fp: u64,
    opts_hash: u64,
}

impl Store {
    pub(super) fn new(dir: &Path, graph_fp: u64, opts_hash: u64) -> Store {
        let file = format!("fdt-memo-v{MEMO_VERSION}-{graph_fp:016x}-{opts_hash:016x}.json");
        Store { path: dir.join(file), graph_fp, opts_hash }
    }

    pub(super) fn path(&self) -> &Path {
        &self.path
    }

    /// Load previously persisted entries. `Ok(vec![])` on a missing file
    /// (a plain cold start); `Err` on anything unreadable or inconsistent
    /// — the caller records the warning and proceeds cold.
    pub(super) fn load(&self) -> Result<Vec<(u64, Screen)>, FdtError> {
        let text = match std::fs::read_to_string(&self.path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(self.err(format!("unreadable: {e}"))),
        };
        let doc = parse(&text).map_err(|r| self.err(format!("corrupt JSON ({r})")))?;
        if doc.version != MEMO_VERSION {
            return Err(self.err(format!(
                "version {} (this build writes {MEMO_VERSION}); stale cache ignored",
                doc.version
            )));
        }
        if doc.graph_fp != self.graph_fp || doc.opts_hash != self.opts_hash {
            return Err(self.err(format!(
                "fingerprint mismatch (file {:016x}/{:016x}, expected {:016x}/{:016x})",
                doc.graph_fp, doc.opts_hash, self.graph_fp, self.opts_hash
            )));
        }
        Ok(doc
            .entries
            .into_iter()
            .map(|(fp, v)| (fp, if v < 0 { Screen::Invalid } else { Screen::Ram(v as usize) }))
            .collect())
    }

    /// Persist `entries` atomically (temp file + rename). Failures are
    /// typed warnings — a read-only cache dir must not fail the flow.
    pub(super) fn save(&self, entries: &[(u64, Screen)]) -> Result<(), FdtError> {
        let Some(dir) = self.path.parent() else {
            return Err(self.err("no parent directory".to_string()));
        };
        std::fs::create_dir_all(dir).map_err(|e| self.err(format!("cannot create dir: {e}")))?;
        let mut body = String::with_capacity(64 + entries.len() * 24);
        body.push_str(&format!(
            "{{\"version\":{MEMO_VERSION},\"graph_fp\":\"{:016x}\",\"opts_hash\":\"{:016x}\",\"entries\":[",
            self.graph_fp, self.opts_hash
        ));
        let mut emitted = 0usize;
        for (fp, s) in entries {
            let v: i64 = match s {
                Screen::Invalid => -1,
                Screen::Ram(r) => i64::try_from(*r).unwrap_or(i64::MAX),
                Screen::AboveIncumbent => continue, // cutoff-relative; never persisted
            };
            if emitted > 0 {
                body.push(',');
            }
            emitted += 1;
            body.push_str(&format!("[\"{fp:016x}\",{v}]"));
        }
        body.push_str("]}\n");
        let tmp = self.path.with_extension("json.tmp");
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(body.as_bytes())?;
            f.sync_all()?;
            std::fs::rename(&tmp, &self.path)
        };
        write().map_err(|e| {
            let _ = std::fs::remove_file(&tmp);
            self.err(format!("cannot write: {e}"))
        })
    }

    fn err(&self, reason: String) -> FdtError {
        FdtError::MemoCache { path: self.path.display().to_string(), reason }
    }
}

struct Doc {
    version: u64,
    graph_fp: u64,
    opts_hash: u64,
    entries: Vec<(u64, i64)>,
}

/// Strict recursive-descent parser for exactly the shape [`Store::save`]
/// writes (`serde` is not in the offline vendor set). Anything else —
/// truncation, garbage, type confusion — is a parse error, which the
/// loader surfaces as a typed corrupt-cache warning.
fn parse(text: &str) -> Result<Doc, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    p.expect(b'{')?;
    let mut version = None;
    let mut graph_fp = None;
    let mut opts_hash = None;
    let mut entries = None;
    loop {
        p.ws();
        let key = p.string()?;
        p.ws();
        p.expect(b':')?;
        p.ws();
        match key.as_str() {
            "version" => version = Some(p.integer()? as u64),
            "graph_fp" => graph_fp = Some(p.hex_string()?),
            "opts_hash" => opts_hash = Some(p.hex_string()?),
            "entries" => {
                let mut es = Vec::new();
                p.expect(b'[')?;
                p.ws();
                if p.peek() == Some(b']') {
                    p.i += 1;
                } else {
                    loop {
                        p.ws();
                        p.expect(b'[')?;
                        p.ws();
                        let fp = p.hex_string()?;
                        p.ws();
                        p.expect(b',')?;
                        p.ws();
                        let v = p.integer()?;
                        p.ws();
                        p.expect(b']')?;
                        es.push((fp, v));
                        p.ws();
                        match p.next() {
                            Some(b',') => continue,
                            Some(b']') => break,
                            _ => return Err("expected ',' or ']' in entries".to_string()),
                        }
                    }
                }
                entries = Some(es);
            }
            other => return Err(format!("unexpected key `{other}`")),
        }
        p.ws();
        match p.next() {
            Some(b',') => continue,
            Some(b'}') => break,
            _ => return Err("expected ',' or '}'".to_string()),
        }
    }
    Ok(Doc {
        version: version.ok_or("missing version")?,
        graph_fp: graph_fp.ok_or("missing graph_fp")?,
        opts_hash: opts_hash.ok_or("missing opts_hash")?,
        entries: entries.ok_or("missing entries")?,
    })
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn next(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }
    fn expect(&mut self, c: u8) -> Result<(), String> {
        match self.next() {
            Some(got) if got == c => Ok(()),
            got => Err(format!("expected `{}`, got {:?}", c as char, got.map(|g| g as char))),
        }
    }
    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let start = self.i;
        while let Some(c) = self.peek() {
            if c == b'"' {
                let s = std::str::from_utf8(&self.b[start..self.i])
                    .map_err(|_| "non-utf8 string".to_string())?
                    .to_string();
                self.i += 1;
                return Ok(s);
            }
            if c == b'\\' {
                return Err("escapes unsupported".to_string());
            }
            self.i += 1;
        }
        Err("unterminated string".to_string())
    }
    fn hex_string(&mut self) -> Result<u64, String> {
        let s = self.string()?;
        u64::from_str_radix(&s, 16).map_err(|e| format!("bad hex `{s}`: {e}"))
    }
    fn integer(&mut self) -> Result<i64, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.i == start {
            return Err("expected integer".to_string());
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "non-utf8 number".to_string())?
            .parse::<i64>()
            .map_err(|e| format!("bad integer: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        // CARGO_TARGET_TMPDIR only exists for integration tests/benches;
        // unit tests get a pid-scoped corner of the system temp dir.
        let d = std::env::temp_dir().join(format!("fdt-memo-unit-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn round_trips_entries() {
        let dir = tmpdir("roundtrip");
        let store = Store::new(&dir, 0xabc, 0xdef);
        assert!(store.load().unwrap().is_empty(), "missing file is a silent cold start");
        let entries =
            vec![(1u64, Screen::Invalid), (2, Screen::Ram(4096)), (3, Screen::AboveIncumbent)];
        store.save(&entries).unwrap();
        let back = store.load().unwrap();
        // AboveIncumbent is cutoff-relative and dropped on write.
        assert_eq!(back.len(), 2);
        assert!(back.contains(&(1, Screen::Invalid)));
        assert!(back.contains(&(2, Screen::Ram(4096))));
    }

    #[test]
    fn wrong_keys_and_corruption_are_typed_errors() {
        let dir = tmpdir("corrupt");
        let store = Store::new(&dir, 7, 9);
        store.save(&[(1, Screen::Ram(10))]).unwrap();
        // Mismatched expected keys (same file on disk, different graph).
        let other = Store { path: store.path.clone(), graph_fp: 8, opts_hash: 9 };
        let e = other.load().unwrap_err();
        assert!(matches!(&e, FdtError::MemoCache { reason, .. } if reason.contains("mismatch")), "{e}");
        // Garbage body.
        std::fs::write(&store.path, "{\"version\": nope").unwrap();
        let e = store.load().unwrap_err();
        assert!(matches!(&e, FdtError::MemoCache { reason, .. } if reason.contains("corrupt")), "{e}");
        // Wrong version.
        std::fs::write(
            &store.path,
            "{\"version\":999,\"graph_fp\":\"0000000000000007\",\"opts_hash\":\"0000000000000009\",\"entries\":[]}",
        )
        .unwrap();
        let e = store.load().unwrap_err();
        assert!(matches!(&e, FdtError::MemoCache { reason, .. } if reason.contains("version")), "{e}");
    }

    #[test]
    fn default_dir_honours_env_override() {
        // Can't mutate the process env safely under the parallel test
        // harness; just assert the fallback chain yields *some* directory
        // on a machine with HOME set, and that FDT_MEMO_DIR (when set by
        // the harness) wins.
        if let Ok(d) = std::env::var("FDT_MEMO_DIR") {
            assert_eq!(default_dir(), Some(PathBuf::from(d)));
        } else if std::env::var("HOME").is_ok_and(|h| !h.is_empty()) {
            assert!(default_dir().is_some());
        }
    }
}
