//! # fdt — Fused Depthwise Tiling for TinyML memory optimization
//!
//! Reproduction of *"Fused Depthwise Tiling for Memory Optimization in
//! TinyML Deep Neural Network Inference"* (Stahl et al., tinyML Research
//! Symposium 2023).
//!
//! The crate implements the paper's full automated tiling exploration flow
//! (Fig. 3) plus every substrate it depends on:
//!
//! * [`graph`] — a TinyML DNN graph IR with shape inference and a
//!   TVM-style operator-fusion analysis.
//! * [`analysis`] — MAC counting, buffer sizing, liveness, memory
//!   profiles and series-parallel decomposition.
//! * [`sched`] — memory-aware scheduling: exact branch-and-bound (the
//!   paper's MILP substitute), the Liu/Kayaaslan series-parallel optimal
//!   algorithm and the hill–valley heuristic.
//! * [`layout`] — memory layout planning: exact branch-and-bound placer
//!   (the paper's Gurobi MILP substitute) plus the TVM-style
//!   hill-climbing/simulated-annealing baseline it is compared against.
//! * [`tiling`] — block-based path discovery (§4.3) and FFMT halo math.
//! * [`transform`] — automated graph transformation (§4.4): FDT
//!   fan-out/fan-in + merge, FFMT spatial tiling, PART, SPLIT/CONCAT.
//! * [`exec`] — a reference interpreter used to prove that tiled graphs
//!   are numerically identical to the untiled originals.
//! * [`models`] — the seven evaluated models (KWS, TXT, MW, POS, SSD,
//!   CIF, RAD) plus a SwiftNet-like scheduling stress graph.
//! * [`coordinator`] — the end-to-end exploration loop of Fig. 3.
//! * [`runtime`] — PJRT loading/execution of the JAX/Pallas AOT
//!   artifacts (`artifacts/*.hlo.txt`) from the request path, with a
//!   [`runtime::FailoverEngine`] degradation chain onto the CPU int8
//!   executor.
//! * [`error`] / [`budget`] — the fault-tolerance layer: typed
//!   [`error::FdtError`] diagnostics and anytime [`budget::Budget`]
//!   limits for the exact solvers.
//! * [`testing`] — deterministic fault injection (`testing::chaos`) and
//!   the random-graph generators backing the no-panic fuzz suite.
//! * [`verify`] — the static plan verifier: an independent
//!   lifetime/aliasing oracle (liveness re-derivation, arena overlap
//!   proofs, symbolic view intervals) that every emitted plan must pass.
//! * [`report`] — regenerates every table and figure of the paper.

// Library code must surface failures as typed `Result`s, not panics —
// tests and benches may still unwrap freely.
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod analysis;
pub mod bench;
pub mod budget;
pub mod codegen;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod graph;
pub mod layout;
pub mod models;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod testing;
pub mod tiling;
pub mod transform;
pub mod util;
pub mod verify;

pub use budget::Budget;
pub use error::{FdtError, FdtResult, PlanViolation, VerifyCheck};
pub use graph::{ActKind, DType, Graph, Op, OpId, OpKind, Padding, Tensor, TensorId, TensorKind};
