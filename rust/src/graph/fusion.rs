//! TVM-style operator fusion as an *analysis* (not a graph mutation).
//!
//! TVM's AoT backend fuses anchor operations (conv / dense / pool / …)
//! with trailing injective elementwise ops (bias add, activation) and
//! leading pads, so the tensors *between* fused ops never materialize and
//! do not contribute to peak memory (paper §4.5). We reproduce this by
//! grouping primitive ops; scheduling, liveness and layout all operate on
//! the group DAG, while path discovery sees the primitive graph ("all
//! fused operations are transformed into their fine-grained operations").

use super::{Graph, OpId, OpKind, TensorId, TensorKind};

/// Index of a fusion group.
pub type GroupId = usize;

/// Result of the fusion analysis.
#[derive(Debug, Clone)]
pub struct Grouping {
    /// op -> group.
    pub group_of: Vec<GroupId>,
    /// group -> member ops, in execution order.
    pub groups: Vec<Vec<OpId>>,
    /// group -> tensors it materializes (group outputs that escape).
    pub outputs: Vec<Vec<TensorId>>,
    /// group -> RAM tensors it reads from other groups / model inputs.
    pub inputs: Vec<Vec<TensorId>>,
}

impl Grouping {
    pub fn len(&self) -> usize {
        self.groups.len()
    }
    pub fn is_empty(&self) -> bool {
        self.groups.is_empty()
    }

    /// Group-level predecessor sets (by group id, deduplicated).
    pub fn preds(&self, g: &Graph) -> Vec<Vec<GroupId>> {
        let producers = g.producers();
        let mut preds: Vec<Vec<GroupId>> = vec![Vec::new(); self.groups.len()];
        for (gid, ins) in self.inputs.iter().enumerate() {
            for &t in ins {
                if let Some(p) = producers[t] {
                    let pg = self.group_of[p];
                    if pg != gid && !preds[gid].contains(&pg) {
                        preds[gid].push(pg);
                    }
                }
            }
        }
        preds
    }

    /// Group-level successor sets.
    pub fn succs(&self, g: &Graph) -> Vec<Vec<GroupId>> {
        let mut succs: Vec<Vec<GroupId>> = vec![Vec::new(); self.groups.len()];
        for (gid, ps) in self.preds(g).iter().enumerate() {
            for &p in ps {
                if !succs[p].contains(&gid) {
                    succs[p].push(gid);
                }
            }
        }
        succs
    }
}

/// Can `kind` fuse into the group of its (sole-consumer) producer?
/// These are the injective elementwise epilogues TVM folds into the
/// anchor op's inner loop.
fn is_epilogue(kind: &OpKind) -> bool {
    matches!(kind, OpKind::BiasAdd | OpKind::Activation(_) | OpKind::Reshape { .. })
}

/// Compute fusion groups over the primitive graph.
///
/// Rules (mirroring TVM's fuse_ops for the AoT micro flow):
/// 1. every op starts as its own group, walked in topo order;
/// 2. an epilogue op (bias / activation / reshape) joins its producer's
///    group if it is the producer's *only* consumer and neither op is
///    marked `no_fuse`;
/// 3. a `Pad` fuses forward into its single consumer when that consumer
///    is a conv-like anchor (TVM folds padding into the conv loop nest).
pub fn fuse(g: &Graph) -> Grouping {
    let consumers = g.consumers();
    let producers = g.producers();
    let order = g.topo_order();
    let nops = g.ops.len();

    // Union-find over ops.
    let mut parent: Vec<usize> = (0..nops).collect();
    fn find(parent: &mut Vec<usize>, x: usize) -> usize {
        if parent[x] != x {
            let r = find(parent, parent[x]);
            parent[x] = r;
            r
        } else {
            x
        }
    }

    for &oid in &order {
        let op = &g.ops[oid];
        if op.no_fuse {
            continue;
        }
        // Rule 2: epilogue joins producer.
        if is_epilogue(&op.kind) {
            let act_in = op.inputs[0];
            if let Some(p) = producers[act_in] {
                let sole = consumers[act_in].len() == 1
                    && !g.outputs.contains(&act_in)
                    && !g.ops[p].no_fuse;
                if sole {
                    let rp = find(&mut parent, p);
                    let ro = find(&mut parent, oid);
                    parent[ro] = rp;
                }
            }
        }
        // Rule 3: pad fuses forward into conv-like sole consumer.
        if matches!(op.kind, OpKind::Pad { .. }) {
            let out = op.output;
            if consumers[out].len() == 1 && !g.outputs.contains(&out) {
                let c = consumers[out][0];
                let conv_like = matches!(
                    g.ops[c].kind,
                    OpKind::Conv2d { .. }
                        | OpKind::DepthwiseConv2d { .. }
                        | OpKind::MaxPool2d { .. }
                        | OpKind::AvgPool2d { .. }
                );
                if conv_like && !g.ops[c].no_fuse {
                    let rc = find(&mut parent, c);
                    let ro = find(&mut parent, oid);
                    parent[rc] = ro; // same set; root choice irrelevant
                }
            }
        }
    }

    // Collect groups in topo order of their first member.
    let mut root_to_gid: Vec<Option<GroupId>> = vec![None; nops];
    let mut groups: Vec<Vec<OpId>> = Vec::new();
    let mut group_of = vec![0usize; nops];
    for &oid in &order {
        let r = find(&mut parent, oid);
        let gid = match root_to_gid[r] {
            Some(gid) => gid,
            None => {
                let gid = groups.len();
                root_to_gid[r] = Some(gid);
                groups.push(Vec::new());
                gid
            }
        };
        groups[gid].push(oid);
        group_of[oid] = gid;
    }

    // Materialized outputs: tensors produced in a group and consumed
    // outside it (or model outputs).
    let mut outputs: Vec<Vec<TensorId>> = vec![Vec::new(); groups.len()];
    let mut inputs: Vec<Vec<TensorId>> = vec![Vec::new(); groups.len()];
    for (gid, members) in groups.iter().enumerate() {
        for &oid in members {
            let out = g.ops[oid].output;
            let escapes = g.outputs.contains(&out)
                || consumers[out].iter().any(|&c| group_of[c] != gid);
            if escapes && !outputs[gid].contains(&out) {
                outputs[gid].push(out);
            }
            for &t in &g.ops[oid].inputs {
                let tensor = g.tensor(t);
                if tensor.kind == TensorKind::Weight {
                    continue;
                }
                let internal = producers[t].map(|p| group_of[p] == gid).unwrap_or(false);
                if !internal && !inputs[gid].contains(&t) {
                    inputs[gid].push(t);
                }
            }
        }
    }

    Grouping { group_of, groups, outputs, inputs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ActKind, DType, GraphBuilder, Padding};

    #[test]
    fn conv_bias_relu_fuses_into_one_group() {
        let mut b = GraphBuilder::new("f");
        let x = b.input("x", vec![8, 8, 3], DType::I8);
        let y = b.conv2d(x, 8, (3, 3), (1, 1), Padding::Same, ActKind::Relu);
        let z = b.conv2d(y, 8, (3, 3), (1, 1), Padding::Same, ActKind::Relu);
        let g = b.finish(vec![z]);
        let grouping = fuse(&g);
        // conv+bias+relu, conv+bias+relu -> 2 groups.
        assert_eq!(grouping.len(), 2);
        // Only the two group outputs materialize.
        assert_eq!(grouping.outputs.iter().flatten().count(), 2);
    }

    #[test]
    fn no_fuse_flag_blocks_fusion() {
        let mut b = GraphBuilder::new("nf");
        let x = b.input("x", vec![16], DType::I8);
        let y = b.dense_act(x, 8, ActKind::Relu);
        let mut g = b.finish(vec![y]);
        for op in &mut g.ops {
            op.no_fuse = true;
        }
        let grouping = fuse(&g);
        assert_eq!(grouping.len(), 3); // dense, bias, relu all separate
    }

    #[test]
    fn branch_point_is_not_fused() {
        // y feeds both relu and a second conv: bias can fuse, but the
        // branch output must materialize.
        let mut b = GraphBuilder::new("br");
        let x = b.input("x", vec![8, 8, 3], DType::I8);
        let y = b.conv2d(x, 4, (3, 3), (1, 1), Padding::Same, ActKind::Identity);
        let a = b.conv2d(y, 4, (1, 1), (1, 1), Padding::Valid, ActKind::Relu);
        let c = b.conv2d(y, 4, (3, 3), (1, 1), Padding::Same, ActKind::Relu);
        let s = b.op(crate::graph::OpKind::Add, vec![a, c]);
        let g = b.finish(vec![s]);
        let grouping = fuse(&g);
        // groups: conv1(+bias), conv2(+bias+relu), conv3(+bias+relu), add
        assert_eq!(grouping.len(), 4);
    }

    #[test]
    fn pad_fuses_into_conv() {
        let mut b = GraphBuilder::new("p");
        let x = b.input("x", vec![8, 8, 3], DType::I8);
        let p = b.op(
            crate::graph::OpKind::Pad { pads: vec![(1, 1), (1, 1), (0, 0)] },
            vec![x],
        );
        let y = b.conv2d(p, 4, (3, 3), (1, 1), Padding::Valid, ActKind::Relu);
        let g = b.finish(vec![y]);
        let grouping = fuse(&g);
        assert_eq!(grouping.len(), 1);
    }
}
