//! DNN graph intermediate representation.
//!
//! The IR mirrors what a TinyML deployment flow (TVM, TFLM) sees after
//! import: a DAG of quantized tensor operations with static shapes.
//! Activations use NHWC layout with an implicit batch of 1 (shapes are
//! stored without the batch dimension: `[H, W, C]` for images, `[F]` for
//! dense features, `[S, E]` for token sequences).
//!
//! Weights are constant tensors (ROM); intermediate tensors are the
//! run-time buffers (RAM) that the paper's tiling flow optimizes.

pub mod build;
mod shape;
pub mod fusion;

pub use build::{GraphBuilder, Rng};

use crate::error::FdtError;
use std::collections::HashMap;
use std::fmt;

/// Index of a tensor inside [`Graph::tensors`].
pub type TensorId = usize;
/// Index of an op inside [`Graph::ops`].
pub type OpId = usize;

/// Element type of a tensor. All evaluated models are quantized to 8 bits
/// (paper §5); FDT fan-in partial sums are 32-bit accumulators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 8-bit quantized activation / weight.
    I8,
    /// 32-bit accumulator or index.
    I32,
    /// 32-bit float (used by the float reference path / L2 artifacts).
    F32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 | DType::F32 => 4,
        }
    }
}

/// Role of a tensor in the graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Model input — written as a whole by the application; untileable.
    Input,
    /// Produced by an op. RAM unless internal to a fusion group.
    Intermediate,
    /// Constant parameter (ROM).
    Weight,
}

/// Activation function fused into [`OpKind::Activation`] / [`OpKind::Merge`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ActKind {
    Identity,
    Relu,
    Relu6,
    Sigmoid,
    Tanh,
}

/// Spatial padding mode for convolution / pooling ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Padding {
    /// TensorFlow SAME: output spatial size = ceil(in / stride).
    Same,
    /// No padding.
    Valid,
    /// Explicit `((top, bottom), (left, right))`.
    Explicit((usize, usize), (usize, usize)),
}

/// Operation kinds. Activation inputs come first in [`Op::inputs`],
/// followed by weights/bias constants.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// 2-D convolution, NHWC activations, HWIO weights `[kh, kw, cin, cout]`.
    /// Inputs: `[x, w]`.
    Conv2d { stride: (usize, usize), padding: Padding },
    /// Depthwise 2-D convolution, weights `[kh, kw, c]`. Inputs: `[x, w]`.
    DepthwiseConv2d { stride: (usize, usize), padding: Padding },
    /// Fully connected: `y[o] = sum_i x[i] * w[i, o]`. Inputs: `[x, w]`.
    Dense,
    /// Adds a per-channel bias (last axis). Inputs: `[x, b]`.
    BiasAdd,
    /// Elementwise activation function.
    Activation(ActKind),
    MaxPool2d { ksize: (usize, usize), stride: (usize, usize), padding: Padding },
    AvgPool2d { ksize: (usize, usize), stride: (usize, usize), padding: Padding },
    /// Global average pooling over H and W: `[H,W,C] -> [C]`.
    GlobalAvgPool,
    /// Elementwise addition of two activation tensors (residual).
    Add,
    /// Elementwise multiplication of two activation tensors.
    Mul,
    /// Zero padding; one `(before, after)` pair per axis.
    Pad { pads: Vec<(usize, usize)> },
    /// Shape change without data movement.
    Reshape { shape: Vec<usize> },
    Softmax,
    /// Embedding lookup: inputs `[table, indices]`, table `[vocab, emb]`
    /// (weight), indices `[seq]` (i32) -> `[seq, emb]`.
    Gather,
    /// Mean over one axis.
    ReduceMean { axis: usize, keepdims: bool },
    /// Full-rank strided-free slice: `out = x[begins..ends]`.
    Slice { begins: Vec<usize>, ends: Vec<usize> },
    /// Concatenation along `axis`.
    Concat { axis: usize },
    /// FDT merge: elementwise sum of all partial inputs, then activation
    /// (§3, Fig 2). Partial inputs are pre-activation accumulators.
    Merge { act: ActKind },
}

impl OpKind {
    /// Short mnemonic used in op names and DOT dumps.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::DepthwiseConv2d { .. } => "dwconv",
            OpKind::Dense => "dense",
            OpKind::BiasAdd => "bias",
            OpKind::Activation(_) => "act",
            OpKind::MaxPool2d { .. } => "maxpool",
            OpKind::AvgPool2d { .. } => "avgpool",
            OpKind::GlobalAvgPool => "gap",
            OpKind::Add => "add",
            OpKind::Mul => "mul",
            OpKind::Pad { .. } => "pad",
            OpKind::Reshape { .. } => "reshape",
            OpKind::Softmax => "softmax",
            OpKind::Gather => "gather",
            OpKind::ReduceMean { .. } => "mean",
            OpKind::Slice { .. } => "slice",
            OpKind::Concat { .. } => "concat",
            OpKind::Merge { .. } => "merge",
        }
    }
}

/// A tensor: static shape + dtype + role. Weight tensors may carry data
/// for interpreter-based equivalence testing; large zoo models skip data
/// (memory accounting needs only shapes).
#[derive(Debug, Clone)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub kind: TensorKind,
    /// Constant data (weights only, f32 master copy).
    pub data: Option<Vec<f32>>,
}

impl Tensor {
    /// Number of elements.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
    /// Buffer size in bytes.
    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.size()
    }
}

/// An operation node.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
    /// Set by the tiling transform to prevent operator fusion across
    /// partition boundaries (§4.4: the last op of a split path must not
    /// fuse with CONCAT / Merge).
    pub no_fuse: bool,
}

/// A DNN graph: tensors + ops + designated model inputs/outputs.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: Vec<Tensor>,
    pub ops: Vec<Op>,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

impl Graph {
    pub fn new(name: impl Into<String>) -> Self {
        Graph { name: name.into(), ..Default::default() }
    }

    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id]
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id]
    }

    /// The op producing `t`, if any (inputs and weights have none).
    pub fn producer(&self, t: TensorId) -> Option<OpId> {
        self.ops.iter().find(|o| o.output == t).map(|o| o.id)
    }

    /// Map tensor -> producing op, computed once.
    pub fn producers(&self) -> Vec<Option<OpId>> {
        let mut p = vec![None; self.tensors.len()];
        for o in &self.ops {
            p[o.output] = Some(o.id);
        }
        p
    }

    /// Map tensor -> consuming ops.
    pub fn consumers(&self) -> Vec<Vec<OpId>> {
        let mut c: Vec<Vec<OpId>> = vec![Vec::new(); self.tensors.len()];
        for o in &self.ops {
            for &i in &o.inputs {
                c[i].push(o.id);
            }
        }
        c
    }

    /// Ops in a valid topological order (ops are appended in topo order by
    /// the builder; this re-derives one defensively). Panics on a cyclic
    /// graph — use [`Graph::try_topo_order`] (or a [`Graph::validate`]
    /// pre-flight) when the graph is untrusted.
    pub fn topo_order(&self) -> Vec<OpId> {
        match self.try_topo_order() {
            Ok(order) => order,
            Err(e) => panic!("{e}"),
        }
    }

    /// Ops in a valid topological order, or [`FdtError::CyclicGraph`] /
    /// [`FdtError::DanglingTensor`] when no such order exists.
    pub fn try_topo_order(&self) -> Result<Vec<OpId>, FdtError> {
        for op in &self.ops {
            for &t in op.inputs.iter().chain(std::iter::once(&op.output)) {
                if t >= self.tensors.len() {
                    return Err(FdtError::DanglingTensor { op: op.name.clone(), tensor: t });
                }
            }
        }
        let producers = self.producers();
        let mut indeg: Vec<usize> = self
            .ops
            .iter()
            .map(|o| o.inputs.iter().filter(|&&t| producers[t].is_some()).count())
            .collect();
        let mut fanout: Vec<Vec<OpId>> = vec![Vec::new(); self.ops.len()];
        for o in &self.ops {
            for &t in &o.inputs {
                if let Some(p) = producers[t] {
                    fanout[p].push(o.id);
                }
            }
        }
        let mut ready: Vec<OpId> =
            (0..self.ops.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.ops.len());
        while let Some(op) = ready.pop() {
            order.push(op);
            for &s in &fanout[op] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if order.len() != self.ops.len() {
            return Err(FdtError::CyclicGraph { graph: self.name.clone() });
        }
        Ok(order)
    }

    /// Pre-flight validation of structural invariants: dangling tensor
    /// references, missing producers, dependency cycles, op arity,
    /// shape-inference mismatches and zero-extent model inputs. The
    /// coordinator runs this before discovery; any graph that passes is
    /// safe to feed through the whole flow without panicking.
    pub fn validate(&self) -> Result<(), FdtError> {
        // Referential integrity first — nothing below may index out of
        // bounds on an arbitrary (e.g. fuzz-mutated) graph.
        for op in &self.ops {
            for &t in op.inputs.iter().chain(std::iter::once(&op.output)) {
                if t >= self.tensors.len() {
                    return Err(FdtError::DanglingTensor { op: op.name.clone(), tensor: t });
                }
            }
            if op.inputs.is_empty() {
                return Err(FdtError::InvalidOp {
                    op: op.name.clone(),
                    reason: "op has no inputs".to_string(),
                });
            }
            let min_arity = match op.kind {
                OpKind::Conv2d { .. }
                | OpKind::DepthwiseConv2d { .. }
                | OpKind::Dense
                | OpKind::BiasAdd
                | OpKind::Gather
                | OpKind::Add
                | OpKind::Mul => 2,
                _ => 1,
            };
            if op.inputs.len() < min_arity {
                return Err(FdtError::InvalidOp {
                    op: op.name.clone(),
                    reason: format!(
                        "{} needs {} inputs, has {}",
                        op.kind.mnemonic(),
                        min_arity,
                        op.inputs.len()
                    ),
                });
            }
        }
        for &t in self.inputs.iter().chain(self.outputs.iter()) {
            if t >= self.tensors.len() {
                return Err(FdtError::DanglingTensor { op: "<model io>".to_string(), tensor: t });
            }
        }
        // Model inputs must have positive extent everywhere (zero-sized
        // *intermediates* — e.g. empty slices — are legal and inert).
        for &i in &self.inputs {
            let t = &self.tensors[i];
            if t.shape.contains(&0) {
                return Err(FdtError::ZeroExtentDim {
                    tensor: t.name.clone(),
                    shape: t.shape.clone(),
                });
            }
        }
        let producers = self.producers();
        for op in &self.ops {
            for &t in &op.inputs {
                let tensor = &self.tensors[t];
                if tensor.kind == TensorKind::Intermediate && producers[t].is_none() {
                    return Err(FdtError::MissingProducer {
                        op: op.name.clone(),
                        tensor: tensor.name.clone(),
                    });
                }
            }
            let expect = shape::infer(self, op).map_err(|e| FdtError::InvalidOp {
                op: op.name.clone(),
                reason: e,
            })?;
            let got = &self.tensors[op.output];
            if expect.shape != got.shape {
                return Err(FdtError::ShapeMismatch {
                    op: op.name.clone(),
                    inferred: expect.shape,
                    stored: got.shape.clone(),
                });
            }
        }
        for &o in &self.outputs {
            if producers[o].is_none() {
                return Err(FdtError::OutputWithoutProducer {
                    tensor: self.tensors[o].name.clone(),
                });
            }
        }
        // Acyclicity.
        self.try_topo_order()?;
        Ok(())
    }

    /// Structural fingerprint of the graph: a 64-bit hash over op kinds
    /// and parameters, tensor shapes/dtypes/roles, wiring and fusion
    /// barriers — everything the scheduler, layout planner and MAC
    /// counter depend on. Names and weight *values* are excluded, so two
    /// tiling transforms producing structurally identical graphs share a
    /// fingerprint and the coordinator solves them once.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = crate::util::Fnv::default();
        self.tensors.len().hash(&mut h);
        self.ops.len().hash(&mut h);
        for t in &self.tensors {
            t.shape.hash(&mut h);
            t.dtype.hash(&mut h);
            t.kind.hash(&mut h);
        }
        for o in &self.ops {
            o.kind.hash(&mut h);
            o.inputs.hash(&mut h);
            o.output.hash(&mut h);
            o.no_fuse.hash(&mut h);
        }
        self.inputs.hash(&mut h);
        self.outputs.hash(&mut h);
        h.finish()
    }

    /// Total weight bytes (ROM).
    pub fn rom_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.bytes())
            .sum()
    }

    /// Graphviz DOT dump (ops as boxes, RAM tensors as ellipses).
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name);
        for t in &self.tensors {
            if t.kind == TensorKind::Weight {
                continue;
            }
            s += &format!(
                "  t{} [label=\"{}\\n{:?} {:?}\", shape=ellipse];\n",
                t.id, t.name, t.shape, t.dtype
            );
        }
        for o in &self.ops {
            s += &format!("  o{} [label=\"{}\", shape=box];\n", o.id, o.name);
            for &i in &o.inputs {
                if self.tensors[i].kind != TensorKind::Weight {
                    s += &format!("  t{} -> o{};\n", i, o.id);
                }
            }
            s += &format!("  o{} -> t{};\n", o.id, o.output);
        }
        s += "}\n";
        s
    }

    /// Summary statistics line.
    pub fn summary(&self) -> String {
        let ram_tensors = self
            .tensors
            .iter()
            .filter(|t| t.kind != TensorKind::Weight)
            .count();
        format!(
            "{}: {} ops, {} tensors ({} RAM), {:.1} kB ROM",
            self.name,
            self.ops.len(),
            self.tensors.len(),
            ram_tensors,
            self.rom_bytes() as f64 / 1024.0
        )
    }
}

impl fmt::Display for Graph {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.summary())?;
        let mut by_tensor: HashMap<TensorId, &str> = HashMap::new();
        for t in &self.tensors {
            by_tensor.insert(t.id, &t.name);
        }
        for op in &self.ops {
            let ins: Vec<&str> = op.inputs.iter().map(|i| by_tensor[i]).collect();
            writeln!(
                f,
                "  {:24} {:8} ({}) -> {} {:?}",
                op.name,
                op.kind.mnemonic(),
                ins.join(", "),
                self.tensors[op.output].name,
                self.tensors[op.output].shape
            )?;
        }
        Ok(())
    }
}

pub use shape::{infer as infer_shape, pad_before, window_out, InferredTensor};
