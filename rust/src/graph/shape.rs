//! Shape and dtype inference for every [`OpKind`].

use super::{DType, Graph, Op, OpKind, Padding};

/// Result of shape inference for an op output.
#[derive(Debug, Clone, PartialEq)]
pub struct InferredTensor {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// Output spatial size of a conv/pool window along one axis.
///
/// Returns `(out, pad_before, pad_after)`.
pub fn window_out(input: usize, k: usize, stride: usize, padding: Padding, axis: usize) -> Result<(usize, usize, usize), String> {
    if stride == 0 {
        return Err("window stride must be positive".to_string());
    }
    if input == 0 {
        return Err("zero-extent input to a windowed op".to_string());
    }
    match padding {
        Padding::Valid => {
            if input < k {
                return Err(format!("window {k} larger than input {input} (VALID)"));
            }
            Ok(((input - k) / stride + 1, 0, 0))
        }
        Padding::Same => {
            let out = input.div_ceil(stride);
            let total = ((out - 1) * stride + k).saturating_sub(input);
            let before = total / 2;
            let after = total - before;
            Ok((out, before, after))
        }
        Padding::Explicit(h, w) => {
            let (b, a) = if axis == 0 { h } else { w };
            let padded = input + b + a;
            if padded < k {
                return Err(format!("window {k} larger than padded input {padded}"));
            }
            Ok(((padded - k) / stride + 1, b, a))
        }
    }
}

/// Resolved `(pad_top, pad_left)` of a windowed op — the single source of
/// truth shared by the f32 interpreter, the int8 interpreter and the C
/// emitter, so the split-pad convention cannot drift between execution
/// paths (TF SAME: `total/2` before, remainder after — the extra pad
/// lands at the bottom/right, which matters for even kernels and
/// stride > 1).
pub fn pad_before(
    padding: Padding,
    in_h: usize,
    in_w: usize,
    k: (usize, usize),
    s: (usize, usize),
) -> (isize, isize) {
    if s.0 == 0 || s.1 == 0 || in_h == 0 || in_w == 0 {
        return (0, 0); // degenerate windows are rejected upstream by `window_out`
    }
    match padding {
        Padding::Valid => (0, 0),
        Padding::Same => {
            let oh = in_h.div_ceil(s.0);
            let ow = in_w.div_ceil(s.1);
            let th = ((oh - 1) * s.0 + k.0).saturating_sub(in_h);
            let tw = ((ow - 1) * s.1 + k.1).saturating_sub(in_w);
            ((th / 2) as isize, (tw / 2) as isize)
        }
        Padding::Explicit(h, w) => (h.0 as isize, w.0 as isize),
    }
}

fn spatial(
    x: &[usize],
    k: (usize, usize),
    stride: (usize, usize),
    padding: Padding,
) -> Result<(usize, usize), String> {
    if x.len() != 3 {
        return Err(format!("expected rank-3 NHWC-without-batch input, got {x:?}"));
    }
    let (oh, _, _) = window_out(x[0], k.0, stride.0, padding, 0)?;
    let (ow, _, _) = window_out(x[1], k.1, stride.1, padding, 1)?;
    Ok((oh, ow))
}

/// Infer the output shape/dtype of `op` within `g`.
pub fn infer(g: &Graph, op: &Op) -> Result<InferredTensor, String> {
    let t = |i: usize| -> &super::Tensor { g.tensor(op.inputs[i]) };
    let need = |n: usize| -> Result<(), String> {
        if op.inputs.len() != n {
            Err(format!("expected {n} inputs, got {}", op.inputs.len()))
        } else {
            Ok(())
        }
    };
    // Output dtype defaults to the dtype stored on the output tensor when
    // it widens an accumulator (FDT partials are i32); inference reports
    // the *natural* dtype and `validate` checks shapes only.
    match &op.kind {
        OpKind::Conv2d { stride, padding } => {
            need(2)?;
            let x = &t(0).shape;
            let w = &t(1).shape; // [kh, kw, cin, cout]
            if w.len() != 4 {
                return Err(format!("conv weight must be HWIO rank-4, got {w:?}"));
            }
            if x.len() != 3 {
                return Err(format!("conv input must be rank-3 HWC, got {x:?}"));
            }
            if x[2] != w[2] {
                return Err(format!("conv cin mismatch: input {x:?} vs weight {w:?}"));
            }
            let (oh, ow) = spatial(x, (w[0], w[1]), *stride, *padding)?;
            Ok(InferredTensor { shape: vec![oh, ow, w[3]], dtype: t(0).dtype })
        }
        OpKind::DepthwiseConv2d { stride, padding } => {
            need(2)?;
            let x = &t(0).shape;
            let w = &t(1).shape; // [kh, kw, c]
            if w.len() != 3 {
                return Err(format!("dwconv weight must be rank-3 [kh,kw,c], got {w:?}"));
            }
            if x.len() != 3 {
                return Err(format!("dwconv input must be rank-3 HWC, got {x:?}"));
            }
            if x[2] != w[2] {
                return Err(format!("dwconv channel mismatch: input {x:?} vs weight {w:?}"));
            }
            let (oh, ow) = spatial(x, (w[0], w[1]), *stride, *padding)?;
            Ok(InferredTensor { shape: vec![oh, ow, x[2]], dtype: t(0).dtype })
        }
        OpKind::Dense => {
            need(2)?;
            let x = &t(0).shape;
            let w = &t(1).shape; // [in, out]
            if w.len() != 2 {
                return Err(format!("dense weight must be rank-2, got {w:?}"));
            }
            let in_features: usize = x.iter().product();
            if in_features != w[0] {
                return Err(format!("dense in mismatch: input {x:?} vs weight {w:?}"));
            }
            Ok(InferredTensor { shape: vec![w[1]], dtype: t(0).dtype })
        }
        OpKind::BiasAdd => {
            need(2)?;
            let x = &t(0).shape;
            let b = &t(1).shape;
            if b.len() != 1 || x.last() != Some(&b[0]) {
                return Err(format!("bias {b:?} does not match last axis of {x:?}"));
            }
            Ok(InferredTensor { shape: x.clone(), dtype: t(0).dtype })
        }
        OpKind::Activation(_) | OpKind::Softmax => {
            need(1)?;
            Ok(InferredTensor { shape: t(0).shape.clone(), dtype: t(0).dtype })
        }
        OpKind::MaxPool2d { ksize, stride, padding }
        | OpKind::AvgPool2d { ksize, stride, padding } => {
            need(1)?;
            let x = &t(0).shape;
            let (oh, ow) = spatial(x, *ksize, *stride, *padding)?;
            Ok(InferredTensor { shape: vec![oh, ow, x[2]], dtype: t(0).dtype })
        }
        OpKind::GlobalAvgPool => {
            need(1)?;
            let x = &t(0).shape;
            if x.len() != 3 {
                return Err(format!("gap expects rank-3, got {x:?}"));
            }
            Ok(InferredTensor { shape: vec![x[2]], dtype: t(0).dtype })
        }
        OpKind::Add | OpKind::Mul => {
            need(2)?;
            if t(0).shape != t(1).shape {
                return Err(format!(
                    "elementwise shape mismatch: {:?} vs {:?}",
                    t(0).shape,
                    t(1).shape
                ));
            }
            Ok(InferredTensor { shape: t(0).shape.clone(), dtype: t(0).dtype })
        }
        OpKind::Pad { pads } => {
            need(1)?;
            let x = &t(0).shape;
            if pads.len() != x.len() {
                return Err(format!("pad rank mismatch: {pads:?} vs {x:?}"));
            }
            let shape = x
                .iter()
                .zip(pads)
                .map(|(&d, &(b, a))| d + b + a)
                .collect();
            Ok(InferredTensor { shape, dtype: t(0).dtype })
        }
        OpKind::Reshape { shape } => {
            need(1)?;
            let n: usize = t(0).shape.iter().product();
            let m: usize = shape.iter().product();
            if n != m {
                return Err(format!("reshape numel mismatch: {n} vs {m}"));
            }
            Ok(InferredTensor { shape: shape.clone(), dtype: t(0).dtype })
        }
        OpKind::Gather => {
            need(2)?;
            let table = &t(0).shape; // [vocab, emb] weight
            let idx = &t(1).shape; // [seq]
            if table.len() != 2 || idx.len() != 1 {
                return Err(format!("gather expects table rank-2 + indices rank-1, got {table:?} / {idx:?}"));
            }
            Ok(InferredTensor { shape: vec![idx[0], table[1]], dtype: t(0).dtype })
        }
        OpKind::ReduceMean { axis, keepdims } => {
            need(1)?;
            let x = &t(0).shape;
            if *axis >= x.len() {
                return Err(format!("mean axis {axis} out of range for {x:?}"));
            }
            let mut shape = x.clone();
            if *keepdims {
                shape[*axis] = 1;
            } else {
                shape.remove(*axis);
            }
            Ok(InferredTensor { shape, dtype: t(0).dtype })
        }
        OpKind::Slice { begins, ends } => {
            need(1)?;
            let x = &t(0).shape;
            if begins.len() != x.len() || ends.len() != x.len() {
                return Err(format!("slice rank mismatch: {begins:?}/{ends:?} vs {x:?}"));
            }
            let mut shape = Vec::with_capacity(x.len());
            for i in 0..x.len() {
                // `begins == ends` is a legal empty slice (zero-sized
                // buffers are inert throughout the flow).
                if begins[i] > ends[i] || ends[i] > x[i] {
                    return Err(format!(
                        "slice bounds [{}, {}) invalid for axis {i} of {x:?}",
                        begins[i], ends[i]
                    ));
                }
                shape.push(ends[i] - begins[i]);
            }
            Ok(InferredTensor { shape, dtype: t(0).dtype })
        }
        OpKind::Concat { axis } => {
            if op.inputs.is_empty() {
                return Err("concat needs at least one input".into());
            }
            let first = &t(0).shape;
            if *axis >= first.len() {
                return Err(format!("concat axis {axis} out of range for {first:?}"));
            }
            let mut total = 0;
            for k in 0..op.inputs.len() {
                let s = &t(k).shape;
                if s.len() != first.len() {
                    return Err(format!("concat rank mismatch: {s:?} vs {first:?}"));
                }
                for a in 0..s.len() {
                    if a != *axis && s[a] != first[a] {
                        return Err(format!("concat shape mismatch on axis {a}: {s:?} vs {first:?}"));
                    }
                }
                total += s[*axis];
            }
            let mut shape = first.clone();
            shape[*axis] = total;
            Ok(InferredTensor { shape, dtype: t(0).dtype })
        }
        OpKind::Merge { .. } => {
            if op.inputs.is_empty() {
                return Err("merge needs at least one partial input".into());
            }
            let first = &t(0).shape;
            for k in 1..op.inputs.len() {
                if &t(k).shape != first {
                    return Err(format!(
                        "merge partial shape mismatch: {:?} vs {first:?}",
                        t(k).shape
                    ));
                }
            }
            Ok(InferredTensor { shape: first.clone(), dtype: t(0).dtype })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_before_matches_window_out_over_kernel_stride_grid() {
        // The split-pad convention must agree with shape inference for
        // every (kernel, stride, size) combination — including the even
        // kernels and stride > 1 cases where the floor/ceil split is easy
        // to get wrong.
        for size in 1..=12usize {
            for k in 1..=5usize {
                for s in 1..=3usize {
                    let (_, before, _) = window_out(size, k, s, Padding::Same, 0).unwrap();
                    let (pt, pl) = pad_before(Padding::Same, size, size, (k, k), (s, s));
                    assert_eq!(pt, before as isize, "size {size} k {k} s {s}");
                    assert_eq!(pl, before as isize, "size {size} k {k} s {s}");
                    if size >= k {
                        assert_eq!(
                            pad_before(Padding::Valid, size, size, (k, k), (s, s)),
                            (0, 0)
                        );
                    }
                    let ex = Padding::Explicit((1, 2), (0, 1));
                    let (_, b, _) = window_out(size + 3, k, s, ex, 0).unwrap();
                    assert_eq!(b, 1, "explicit pad-before must pass through");
                    assert_eq!(pad_before(ex, size, size, (k, k), (s, s)), (1, 0));
                }
            }
        }
    }
}
