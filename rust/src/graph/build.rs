//! Fluent graph construction with automatic shape inference.
//!
//! The builder owns a [`Graph`] under construction; op-adding methods
//! return the output [`TensorId`] so layers chain naturally:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla rpath link flags,
//! // so running would fail to locate libstdc++ from /opt/xla_extension)
//! use fdt::graph::{GraphBuilder, DType, Padding, ActKind};
//! let mut b = GraphBuilder::new("tiny");
//! let x = b.input("x", vec![8, 8, 4], DType::I8);
//! let y = b.conv2d(x, 8, (3, 3), (1, 1), Padding::Same, ActKind::Relu);
//! let z = b.global_avg_pool(y);
//! let out = b.dense_act(z, 2, ActKind::Identity);
//! let g = b.finish(vec![out]);
//! assert!(g.validate().is_ok());
//! ```

use super::shape::infer;
use super::{ActKind, DType, Graph, Op, OpKind, Padding, Tensor, TensorId, TensorKind};

/// Deterministic xorshift PRNG for synthetic weights — weights only need
/// to be reproducible, not statistically strong.
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
    /// Uniform in [-0.5, 0.5).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32 - 0.5
    }
}

/// Fluent builder; see module docs.
pub struct GraphBuilder {
    g: Graph,
    rng: Rng,
    /// When false, weight tensors carry no data (large zoo models).
    pub with_data: bool,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { g: Graph::new(name), rng: Rng::new(0x5eed), with_data: true }
    }

    /// Builder for large models where interpreter execution is not needed.
    pub fn without_data(name: impl Into<String>) -> Self {
        let mut b = Self::new(name);
        b.with_data = false;
        b
    }

    fn add_tensor(
        &mut self,
        name: String,
        shape: Vec<usize>,
        dtype: DType,
        kind: TensorKind,
        data: Option<Vec<f32>>,
    ) -> TensorId {
        let id = self.g.tensors.len();
        self.g.tensors.push(Tensor { id, name, shape, dtype, kind, data });
        id
    }

    /// Declare a model input.
    pub fn input(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> TensorId {
        let id = self.add_tensor(name.to_string(), shape, dtype, TensorKind::Input, None);
        self.g.inputs.push(id);
        id
    }

    /// Declare a constant weight with deterministic synthetic data.
    pub fn weight(&mut self, name: &str, shape: Vec<usize>, dtype: DType) -> TensorId {
        let data = if self.with_data {
            let n: usize = shape.iter().product();
            // Scale down so deep nets keep activations in a sane range.
            let scale = 1.0 / (n as f32).sqrt().max(1.0);
            Some((0..n).map(|_| self.rng.next_f32() * scale).collect())
        } else {
            None
        };
        self.add_tensor(name.to_string(), shape, dtype, TensorKind::Weight, data)
    }

    /// Declare a weight with explicit data.
    pub fn weight_with(&mut self, name: &str, shape: Vec<usize>, dtype: DType, data: Vec<f32>) -> TensorId {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        self.add_tensor(name.to_string(), shape, dtype, TensorKind::Weight, Some(data))
    }

    /// Add an op; the output tensor is created with the inferred shape.
    pub fn op(&mut self, kind: OpKind, inputs: Vec<TensorId>) -> TensorId {
        self.op_named(None, kind, inputs)
    }

    /// Add an op with an explicit name.
    pub fn op_named(&mut self, name: Option<String>, kind: OpKind, inputs: Vec<TensorId>) -> TensorId {
        let id = self.g.ops.len();
        let name = name.unwrap_or_else(|| format!("{}_{}", kind.mnemonic(), id));
        // Temporary op for inference (output filled after).
        let tmp = Op { id, name: name.clone(), kind: kind.clone(), inputs: inputs.clone(), output: 0, no_fuse: false };
        let inferred = infer(&self.g, &tmp)
            .unwrap_or_else(|e| panic!("shape inference failed for {name}: {e}"));
        let out = self.add_tensor(
            format!("{name}_out"),
            inferred.shape,
            inferred.dtype,
            TensorKind::Intermediate,
            None,
        );
        self.g.ops.push(Op { id, name, kind, inputs, output: out, no_fuse: false });
        out
    }

    // ---- layer helpers -------------------------------------------------

    /// conv2d + bias + activation (the canonical fused TinyML block).
    pub fn conv2d(
        &mut self,
        x: TensorId,
        cout: usize,
        k: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: ActKind,
    ) -> TensorId {
        let cin =
            *self.g.tensor(x).shape.last().unwrap_or_else(|| panic!("conv2d input is rank 0"));
        let n = self.g.ops.len();
        let w = self.weight(&format!("conv{n}_w"), vec![k.0, k.1, cin, cout], DType::I8);
        let b = self.weight(&format!("conv{n}_b"), vec![cout], DType::I32);
        let y = self.op(OpKind::Conv2d { stride, padding }, vec![x, w]);
        let y = self.op(OpKind::BiasAdd, vec![y, b]);
        self.activation(y, act)
    }

    /// depthwise conv + bias + activation.
    pub fn dwconv(
        &mut self,
        x: TensorId,
        k: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        act: ActKind,
    ) -> TensorId {
        let c =
            *self.g.tensor(x).shape.last().unwrap_or_else(|| panic!("dwconv input is rank 0"));
        let n = self.g.ops.len();
        let w = self.weight(&format!("dw{n}_w"), vec![k.0, k.1, c], DType::I8);
        let b = self.weight(&format!("dw{n}_b"), vec![c], DType::I32);
        let y = self.op(OpKind::DepthwiseConv2d { stride, padding }, vec![x, w]);
        let y = self.op(OpKind::BiasAdd, vec![y, b]);
        self.activation(y, act)
    }

    /// dense + bias + activation.
    pub fn dense_act(&mut self, x: TensorId, out: usize, act: ActKind) -> TensorId {
        let infeat: usize = self.g.tensor(x).shape.iter().product();
        let n = self.g.ops.len();
        let w = self.weight(&format!("fc{n}_w"), vec![infeat, out], DType::I8);
        let b = self.weight(&format!("fc{n}_b"), vec![out], DType::I32);
        let y = self.op(OpKind::Dense, vec![x, w]);
        let y = self.op(OpKind::BiasAdd, vec![y, b]);
        self.activation(y, act)
    }

    /// Identity-aware activation helper (skips Identity).
    pub fn activation(&mut self, x: TensorId, act: ActKind) -> TensorId {
        match act {
            ActKind::Identity => x,
            a => self.op(OpKind::Activation(a), vec![x]),
        }
    }

    /// Global average pooling `[H,W,C] -> [C]`.
    pub fn global_avg_pool(&mut self, x: TensorId) -> TensorId {
        self.op(OpKind::GlobalAvgPool, vec![x])
    }

    /// Embedding lookup: creates the table weight.
    pub fn embedding(&mut self, indices: TensorId, vocab: usize, emb: usize) -> TensorId {
        let n = self.g.ops.len();
        let table = self.weight(&format!("emb{n}_table"), vec![vocab, emb], DType::I8);
        self.op(OpKind::Gather, vec![table, indices])
    }

    pub fn shape_of(&self, t: TensorId) -> &[usize] {
        &self.g.tensor(t).shape
    }

    pub fn graph(&self) -> &Graph {
        &self.g
    }

    /// Finalize: set model outputs and return the graph.
    pub fn finish(mut self, outputs: Vec<TensorId>) -> Graph {
        self.g.outputs = outputs;
        debug_assert!(self.g.validate().is_ok(), "{:?}", self.g.validate());
        self.g
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_small_cnn() {
        let mut b = GraphBuilder::new("t");
        let x = b.input("x", vec![8, 8, 3], DType::I8);
        let y = b.conv2d(x, 16, (3, 3), (1, 1), Padding::Same, ActKind::Relu);
        assert_eq!(b.shape_of(y), &[8, 8, 16]);
        let y = b.op(OpKind::MaxPool2d { ksize: (2, 2), stride: (2, 2), padding: Padding::Valid }, vec![y]);
        assert_eq!(b.shape_of(y), &[4, 4, 16]);
        let y = b.op(OpKind::GlobalAvgPool, vec![y]);
        assert_eq!(b.shape_of(y), &[16]);
        let g = b.finish(vec![y]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn same_padding_matches_tf() {
        // 49x10 input, 10x4 kernel, stride 2x2 SAME -> 25x5 (DS-CNN stem).
        let mut b = GraphBuilder::new("kws_stem");
        let x = b.input("x", vec![49, 10, 1], DType::I8);
        let y = b.conv2d(x, 64, (10, 4), (2, 2), Padding::Same, ActKind::Relu);
        assert_eq!(b.shape_of(y), &[25, 5, 64]);
    }

    #[test]
    fn dense_flattens() {
        let mut b = GraphBuilder::new("d");
        let x = b.input("x", vec![4, 4, 8], DType::I8);
        let y = b.dense_act(x, 10, ActKind::Identity);
        assert_eq!(b.shape_of(y), &[10]);
    }

    #[test]
    fn gather_mean_chain() {
        let mut b = GraphBuilder::new("txt");
        let idx = b.input("tokens", vec![256], DType::I32);
        let e = b.embedding(idx, 10000, 64);
        assert_eq!(b.shape_of(e), &[256, 64]);
        let m = b.op(OpKind::ReduceMean { axis: 0, keepdims: false }, vec![e]);
        assert_eq!(b.shape_of(m), &[64]);
    }

    #[test]
    fn validate_catches_bad_output() {
        let mut b = GraphBuilder::new("v");
        let x = b.input("x", vec![4], DType::I8);
        let y = b.dense_act(x, 3, ActKind::Relu);
        let mut g = b.finish(vec![y]);
        g.tensors[g.ops[0].output].shape = vec![99];
        assert!(g.validate().is_err());
    }
}
